"""Unified substrate runtime: program digests, the compiled-artifact
cache (hit/miss/LRU/content-addressing), the dynamic micro-batcher,
VLIW fast-sim conformance against the checked simulator, and the
Server end-to-end path."""
import numpy as np
import pytest

from repro.core import program
from repro.core.learn import learn_spn, random_spn
from repro.core.processor.config import PTREE
from repro.data import spn_datasets
from repro.queries import QueryEngine, random_mask, sample_ancestral_numpy
from repro.runtime import (ArtifactCache, MicroBatcher, ParityError, Server,
                           canonical, get_substrate, verify_parity)
from repro.runtime.substrates import NumpySubstrate

QUERIES = ("joint", "marginal", "mpe", "sample")
SUBSTRATES = ("numpy", "leveled-jax", "pallas", "vliw-sim", "vliw-mc")


@pytest.fixture(scope="module")
def server(small_spn):
    return Server(small_spn)


def _evidence(num_vars, query, n=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, (n, num_vars))
    if query in ("marginal", "mpe"):
        return random_mask(X, 0.4, seed=seed)
    return X


# ---------------------------------------------------------------------------
# program digest
# ---------------------------------------------------------------------------
def test_digest_stable_across_relearn():
    """Identical re-learned SPNs lower to content-equal programs."""
    X = spn_datasets.load("nltcs", "train", 200)
    d1 = program.lower(learn_spn(X, min_instances=80)).digest()
    d2 = program.lower(learn_spn(X, min_instances=80)).digest()
    assert d1 == d2


def test_digest_distinguishes_programs(small_prog, nltcs_prog):
    assert small_prog.digest() != nltcs_prog.digest()
    # the max-product twin differs only in opcodes — still a new identity
    assert program.to_max_product(small_prog).digest() != small_prog.digest()


def test_digest_tracks_parameter_values(small_prog):
    d0 = small_prog.digest()
    orig = float(small_prog.param_values[0])
    small_prog.param_values[0] = orig + 1.0
    small_prog.invalidate_digest()
    try:
        assert small_prog.digest() != d0
    finally:
        small_prog.param_values[0] = orig
        small_prog.invalidate_digest()
    assert small_prog.digest() == d0


# ---------------------------------------------------------------------------
# artifact cache
# ---------------------------------------------------------------------------
def test_cache_hit_miss_counts(small_prog):
    cache = ArtifactCache(capacity=8)
    sub = get_substrate("numpy")
    a1 = cache.get_or_compile(sub, small_prog, query="marginal")
    a2 = cache.get_or_compile(sub, small_prog, query="marginal")
    assert a1 is a2
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1
    assert sub.compile_count == 1
    cache.get_or_compile(sub, small_prog, query="mpe")   # distinct key
    assert cache.stats()["misses"] == 2 and sub.compile_count == 2


def test_cache_content_addressed(small_spn):
    """Re-lowering the same SPN into a fresh object still hits."""
    cache = ArtifactCache(capacity=8)
    sub = get_substrate("numpy")
    a1 = cache.get_or_compile(sub, program.lower(small_spn))
    a2 = cache.get_or_compile(sub, program.lower(small_spn))
    assert a1 is a2 and sub.compile_count == 1


def test_cache_lru_eviction():
    cache = ArtifactCache(capacity=2)
    sub = get_substrate("numpy")
    progs = [program.lower(random_spn(6, depth=2, num_sums=2,
                                      repetitions=1, seed=s))
             for s in range(3)]
    for p in progs:
        cache.get_or_compile(sub, p)
    assert cache.stats()["evictions"] == 1 and len(cache) == 2
    # progs[0] was evicted -> recompile; progs[2] is resident -> hit
    cache.get_or_compile(sub, progs[2])
    assert cache.stats()["hits"] == 1
    cache.get_or_compile(sub, progs[0])
    assert cache.stats()["misses"] == 4 and sub.compile_count == 4


def test_cache_distinguishes_noc_config(small_prog):
    """Two servers differing only in NoC topology or link width must
    never share an ArtifactCache entry: InterconnectConfig.fingerprint()
    flows through vliw-mc's config_fingerprint() into the cache key."""
    from repro.core.multicore import named_interconnect
    cache = ArtifactCache(capacity=8)
    xbar = get_substrate("vliw-mc", cores=2)
    mesh = get_substrate("vliw-mc", cores=2,
                         interconnect=named_interconnect("mesh"))
    narrow = get_substrate("vliw-mc", cores=2,
                           interconnect=named_interconnect("mesh",
                                                           link_width=8))
    a = cache.get_or_compile(xbar, small_prog, query="marginal")
    b = cache.get_or_compile(mesh, small_prog, query="marginal")
    c = cache.get_or_compile(narrow, small_prog, query="marginal")
    assert a is not b and b is not c and a is not c
    assert cache.stats()["misses"] == 3 and cache.stats()["hits"] == 0
    # identical configs still hit
    assert cache.get_or_compile(mesh, small_prog, query="marginal") is b
    assert cache.stats()["hits"] == 1
    keys = {ArtifactCache.key(small_prog, "marginal", s, 128, True)
            for s in (xbar, mesh, narrow)}
    assert len(keys) == 3
    # server-level: Server(topology=...) builds distinct cache keys too
    s1 = Server(prog=small_prog, substrates=("vliw-mc",), cores=2)
    s2 = Server(prog=small_prog, substrates=("vliw-mc",), cores=2,
                topology="mesh")
    assert (ArtifactCache.key(small_prog, "marginal",
                              s1.substrate("vliw-mc"), 128, True)
            != ArtifactCache.key(small_prog, "marginal",
                                 s2.substrate("vliw-mc"), 128, True))


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------
def test_batcher_coalesces_heterogeneous_requests():
    calls = []

    def execute(leaves):
        calls.append(leaves.shape)
        return leaves.sum(axis=1)

    b = MicroBatcher(execute, tile=128)
    rng = np.random.default_rng(0)
    reqs = [rng.random((n, 7)) for n in (1, 5, 130)]
    pendings = [b.submit(r) for r in reqs]
    out = [p.result() for p in pendings]          # first result() flushes
    assert len(calls) == 1                        # one coalesced execution
    assert calls[0] == (256, 7)                   # 136 rows padded to 2 tiles
    for r, o in zip(reqs, out):
        np.testing.assert_allclose(o, r.sum(axis=1))
    assert b.stats == {"requests": 3, "rows": 136, "batches": 1,
                       "padded_rows": 120}
    assert b.pad_waste == pytest.approx(120 / 256)


def test_batcher_respects_declared_tile():
    """Substrates that take any batch (tile=1) are never padded."""
    shapes = []
    b = MicroBatcher(lambda lv: (shapes.append(lv.shape), lv[:, 0])[1])
    b.submit(np.ones((5, 3)))
    b.flush()
    assert shapes == [(5, 3)] and b.stats["padded_rows"] == 0
    assert b.pad_waste == 0.0


def test_server_reports_padding_waste(small_spn):
    srv = Server(small_spn, substrates=("numpy", "pallas"))
    x = np.abs(_evidence(srv.prog.num_vars, "joint", n=5))
    srv.query(x, "joint", "numpy")      # tile 1: no padding
    srv.query(x, "joint", "pallas")     # lane tile: 5 -> 128
    stats = srv.stats()
    assert stats["padded_rows"] == 123
    assert stats["batchers"]["sum/numpy"]["padded_rows"] == 0
    assert stats["batchers"]["sum/pallas"]["pad_waste"] == \
        pytest.approx(123 / 128, abs=1e-4)


def test_eviction_mid_queue_still_serves(small_spn):
    """A cache eviction between submit and flush must not kill queued
    work: the execute closure holds the artifact only weakly (so the
    WeakKeyDictionary can collect evicted entries), but the batcher
    PINS it strongly while rows are queued — the flush serves from the
    pinned artifact without recompiling."""
    import gc

    srv = Server(small_spn, substrates=("numpy",), cache_capacity=1)
    x = np.abs(_evidence(srv.prog.num_vars, "joint", n=4))
    expected = srv.query(x, "joint", "numpy")
    p = srv.submit(x, "joint", "numpy")
    srv.artifact("mpe", "numpy")        # capacity 1: evicts the queued
    gc.collect()                        # artifact's cache entry
    assert srv.cache.stats()["evictions"] >= 1
    np.testing.assert_array_equal(p.result(), expected)
    # served from the pin, not a recompile: joint + mpe only
    assert srv.cache.stats()["misses"] == 2


def test_batcher_pin_released_after_flush(small_prog):
    """The pin is strong only while rows are queued: once flushed, an
    evicted artifact is collectable again (the pin must not defeat the
    server's weak batcher keying)."""
    import gc
    import weakref

    from repro.runtime import get_substrate as _get

    cache = ArtifactCache(capacity=1)
    sub = _get("numpy")
    art = cache.get_or_compile(sub, small_prog, query="joint")
    b = MicroBatcher(lambda lv: lv[:, 0], pin=art)
    ref = weakref.ref(art)
    b.submit(np.ones((2, 4)))
    assert b._pin is art                # strong while queued
    b.flush()
    assert b._pin is None               # weak again once drained
    cache.get_or_compile(sub, program.lower(
        random_spn(6, depth=2, num_sums=2, repetitions=1, seed=9)),
        query="joint")                  # evict
    del art
    gc.collect()
    assert ref() is None


def test_batcher_auto_flush_at_max_rows():
    calls = []
    b = MicroBatcher(lambda lv: (calls.append(1), lv[:, 0])[1],
                     tile=4, max_rows=8)
    p = b.submit(np.ones((8, 3)))
    assert p.ready() and calls == [1]             # capacity reached -> flush
    b.flush()
    assert calls == [1]                           # empty flush is a no-op


# ---------------------------------------------------------------------------
# VLIW fast-sim conformance (bit-identical to the checked simulator)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("query", QUERIES)
def test_fastsim_bit_identical_small(small_spn, query):
    srv = Server(small_spn, substrates=("vliw-sim",))
    sub = srv.substrate("vliw-sim")
    art = srv.artifact(query, "vliw-sim")
    if query == "sample":
        x = sample_ancestral_numpy(small_spn, 9, seed=3)
    else:
        x = _evidence(srv.prog.num_vars, query, n=9, seed=3)
    leaves = art.prog.leaves_from_evidence(x)
    fast = sub.execute(art, leaves)
    checked = sub.execute_checked(art, leaves)
    np.testing.assert_array_equal(fast, checked)


@pytest.mark.parametrize("query", ["marginal", "mpe"])
def test_fastsim_bit_identical_nltcs(nltcs_spn, query):
    srv = Server(nltcs_spn, substrates=("vliw-sim",))
    sub = srv.substrate("vliw-sim")
    art = srv.artifact(query, "vliw-sim")
    x = _evidence(srv.prog.num_vars, query, n=16, seed=7)
    leaves = art.prog.leaves_from_evidence(x)
    np.testing.assert_array_equal(sub.execute(art, leaves),
                                  sub.execute_checked(art, leaves))


# ---------------------------------------------------------------------------
# server end-to-end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("query", QUERIES)
def test_server_cross_substrate_agreement(server, query):
    if query == "sample":
        x = sample_ancestral_numpy(server.spn, 6, seed=1)
    else:
        x = _evidence(server.prog.num_vars, query)
    ref = server.query(x, query, "numpy")
    assert np.isfinite(ref).all()
    for name in SUBSTRATES[1:]:
        np.testing.assert_allclose(server.query(x, query, name), ref,
                                   atol=1e-4, err_msg=name)


def test_server_second_invocation_is_cache_hit(small_spn):
    """Acceptance: no recompilation for any (SPN, query, substrate) triple."""
    srv = Server(small_spn)
    x = np.abs(_evidence(srv.prog.num_vars, "marginal"))  # joint-valid too

    def hit_all():
        for query in QUERIES:
            for name in SUBSTRATES:
                srv.query(x, query, name)

    hit_all()
    compiles = dict(srv.stats()["compiles"])
    misses = srv.cache.stats()["misses"]
    hit_all()
    assert srv.stats()["compiles"] == compiles
    assert srv.cache.stats()["misses"] == misses
    # one artifact per semiring: joint/marginal/sample share sum-product
    assert all(c == 2 for c in compiles.values())
    assert srv.cache.stats()["hits"] >= len(QUERIES) * len(SUBSTRATES)


def test_server_joint_rejects_partial_evidence(server):
    with pytest.raises(ValueError):
        server.query(np.full((1, server.prog.num_vars), -1), "joint")


def test_server_substrate_aliases(server):
    x = _evidence(server.prog.num_vars, "joint")
    np.testing.assert_array_equal(server.query(x, "joint", "leveled"),
                                  server.query(x, "joint", "leveled-jax"))
    assert canonical("kernel") == "pallas" and canonical("sim") == "vliw-sim"


def test_verify_parity_passes_and_detects(server):
    x = _evidence(server.prog.num_vars, "marginal")
    devs = verify_parity(server, x, query="marginal")
    assert devs["vliw-sim/checked"] == 0.0
    assert max(devs.values()) < 1e-4

    class Broken(NumpySubstrate):
        name = "leveled-jax"   # masquerade as a real backend

        def execute(self, artifact, leaves):
            return super().execute(artifact, leaves) + 0.5

    srv = Server(server.spn)
    srv.substrates["leveled-jax"] = Broken()
    with pytest.raises(ParityError):
        verify_parity(srv, x, query="marginal",
                      substrates=("numpy", "leveled-jax"))


def test_verify_parity_without_numpy_substrate(small_spn):
    """The oracle is built on demand when the server doesn't host one."""
    srv = Server(small_spn, substrates=("leveled-jax",))
    x = _evidence(srv.prog.num_vars, "marginal")
    devs = verify_parity(srv, x, query="marginal")
    assert 0.0 < devs["leveled-jax"] < 1e-4   # f32 vs f64: small, not fake


def test_engine_backend_dispatch_is_cached(small_spn):
    eng = QueryEngine(small_spn)
    x = _evidence(eng.num_vars, "marginal")
    eng.marginal(x, "sim")
    eng.marginal(x, "sim")
    eng.mpe(x, "sim")
    assert eng.substrate("sim").compile_count == 2   # marginal + mpe once
    # vliw_program() routes through the same artifact cache
    assert eng.vliw_program(eng.prog) is eng.artifact("joint", "sim").payload[0]
