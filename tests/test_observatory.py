"""Serving-observatory conformance suite (PR 9).

Pins the four observatory pillars end to end:

- **attribution exactness** — the five cycle-attribution classes
  (issue/stall/barrier/link/inject) sum *bit-exactly* to the checked
  sim's lockstep cycle count, per core, for every
  ``golden_cycles.json`` point (the PR's acceptance criterion);
- **SLO/burn-rate math** — objective resolution, breach accounting,
  burn rate, window pruning and shedding on an injectable fake clock,
  plus the server-level shed path (only with an explicit ``slo=``);
- **telemetry export** — OpenMetrics render/parse round-trip, JSONL
  snapshot stream, and the self-contained observatory report;
- **bench history sentinel** — deterministic fingerprints/metrics,
  append/compare semantics, exact regression gates.

Plus the satellite regressions: ``Histogram.percentile`` edge cases
(property-tested against numpy), ``Server.stats()`` deep-copy
isolation, split-retry ``trace_id`` propagation, the partial Chrome
trace flushed by a crashed ``serve --trace`` run, and the
attribution-guided autotune prior.
"""
import json
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import multicore as mc
from repro.core.multicore.comm import TOPOLOGIES, named_interconnect
from repro.core.processor.config import PTREE
from repro.obs import metrics as obs_metrics
from repro.obs import trace
from repro.obs.attr import (CLASSES, GROUP_OF_CLASS, attribute_artifact,
                            attribute_multicore, attribute_single)
from repro.obs.export import (JsonlExporter, observatory_report,
                              parse_openmetrics, render_openmetrics,
                              write_observatory_report)
from repro.obs.slo import SLObjective, SLOTracker
from repro.runtime import Server
from repro.runtime.batcher import MicroBatcher
from repro.runtime.resilience import Backpressure

from test_noc import GOLDEN_PATH, golden_prog


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# --------------------------------------------------------------------------- #
# attribution exactness: classes sum bit-exactly to lockstep cycles
# --------------------------------------------------------------------------- #
def _golden_points():
    golden = json.loads(GOLDEN_PATH.read_text())
    for ds, per_cores in golden["cycles"].items():
        for cores, per_topo in per_cores.items():
            for topo, want in per_topo.items():
                yield ds, int(cores), topo, int(want)


@pytest.mark.parametrize("dataset,cores,topology,want",
                         list(_golden_points()))
def test_attribution_exact_on_every_golden_point(dataset, cores, topology,
                                                 want):
    """For every golden fixture point the five attribution classes sum
    bit-exactly to the checked sim's cycle count on EVERY core — the
    decomposition never invents or drops a cycle."""
    mcp = mc.compile_multicore(golden_prog(dataset), PTREE, cores,
                               named_interconnect(topology))
    assert int(mcp.meta["cycles"]) == want, "fixture drift: regen golden"
    a = attribute_multicore(mcp)
    assert a.cycles == want
    assert set(a.per_core) == {cp.core for cp in mcp.cores}
    for core, tot in a.per_core.items():
        assert set(tot) == set(CLASSES)
        assert all(v >= 0 for v in tot.values()), (core, tot)
        assert sum(tot.values()) == want, (
            f"{dataset}@{cores}c/{topology} core {core}: attribution "
            f"classes sum to {sum(tot.values())}, not {want}")
    n = len(a.per_core)
    assert sum(a.totals.values()) == n * want
    assert abs(sum(a.fractions.values()) - 1.0) < 1e-5
    assert a.bottleneck in CLASSES
    assert a.bottleneck_group == GROUP_OF_CLASS[a.bottleneck]
    rf = a.roofline
    assert 0.0 < rf["utilization"] <= 1.0
    assert rf["achieved_ops_per_cycle"] <= rf["peak_ops_per_cycle"]


def test_attribution_contended_ring_charges_link_classes(nltcs_prog):
    """On a deliberately narrow 8-core ring the NoC carve-out must
    attribute some waits to latency (stall) AND to contention
    (link/inject) — and every core must still sum exactly."""
    icfg = named_interconnect("ring", link_width=1, hop_latency=4)
    mcp = mc.compile_multicore(nltcs_prog, PTREE, 8, icfg)
    a = attribute_multicore(mcp)
    for tot in a.per_core.values():
        assert sum(tot.values()) == a.cycles
    assert a.totals["stall"] > 0           # hop+serialization latency
    assert a.totals["link"] + a.totals["inject"] > 0   # contention


def test_attribution_single_core_is_all_issue():
    a = attribute_single(cycles=120, useful_ops=600, num_pes=8)
    assert a.per_core == {0: {"issue": 120, "stall": 0, "barrier": 0,
                              "link": 0, "inject": 0}}
    assert a.bottleneck == "issue"
    assert a.bottleneck_group == "compute"
    assert a.roofline["achieved_ops_per_cycle"] == 5.0
    assert a.roofline["comm_ceiling_ops_per_cycle"] is None
    assert a.cycles_per_eval == 120


def test_artifact_meta_attribution_matches_rederivation(nltcs_prog):
    """The attribution attached to artifact meta at compile time equals
    a from-scratch re-derivation from the payload (determinism)."""
    server = Server(prog=nltcs_prog, substrates=("vliw-sim", "vliw-mc"),
                    cores=4, topology="mesh")
    for name in ("vliw-sim", "vliw-mc"):
        art = server.artifact("marginal", name)
        cached = art.meta["attribution"]
        fresh = attribute_artifact(art).to_dict()
        assert cached == fresh
        assert art.meta["bottleneck"] == fresh["bottleneck"]
    stats = server.stats()
    key = "sum/vliw-mc"
    assert stats["multicore"][key]["bottleneck"] in CLASSES


def test_attribute_artifact_none_for_unmodeled_substrates(small_prog):
    server = Server(prog=small_prog, substrates=("numpy",))
    art = server.artifact("marginal", "numpy")
    assert attribute_artifact(art) is None
    assert "attribution" not in art.meta


# --------------------------------------------------------------------------- #
# SLO objectives, burn rate, shedding — on a fake clock
# --------------------------------------------------------------------------- #
def test_slo_burn_rate_math_on_fake_clock():
    clock = FakeClock()
    obj = SLObjective(latency_target_us=100.0, error_budget=0.1,
                      window_s=60.0, min_samples=4, shed_burn_rate=5.0)
    slo = SLOTracker(obj, clock=clock)
    for _ in range(5):               # five in-budget requests
        slo.record("vliw-mc", "sum", 50.0)
        clock.advance(1.0)
    for _ in range(5):               # five over-target requests
        slo.record("vliw-mc", "sum", 500.0)
        clock.advance(1.0)
    s = slo.status("vliw-mc", "sum")
    assert s["window_events"] == 10 and s["breaches"] == 5
    assert s["breach_fraction"] == 0.5
    assert s["burn_rate"] == pytest.approx(0.5 / 0.1)   # 5x budget burn
    assert s["budget_remaining"] == 0.0
    assert not s["healthy"]
    assert s["shedding"] and slo.should_shed("vliw-mc", "sum")


def test_slo_failures_burn_budget():
    clock = FakeClock()
    slo = SLOTracker(SLObjective(latency_target_us=1e9, error_budget=0.5),
                     clock=clock)
    slo.record("numpy", "sum", 1.0, ok=False)
    slo.record("numpy", "sum", 1.0, ok=True)
    s = slo.status("numpy", "sum")
    assert s["breaches"] == 1 and s["breach_fraction"] == 0.5
    assert s["burn_rate"] == 1.0     # burning exactly at the allowed rate
    assert s["healthy"]              # <= budget is still healthy


def test_slo_window_pruning_forgets_old_events():
    clock = FakeClock()
    obj = SLObjective(window_s=10.0, min_samples=1)
    slo = SLOTracker(obj, clock=clock)
    for _ in range(8):
        slo.record("numpy", "sum", 1e9)      # all breaches
    assert slo.status("numpy", "sum")["breaches"] == 8
    clock.advance(11.0)                      # the window rolls past them
    s = slo.status("numpy", "sum")
    assert s["window_events"] == 0 and s["burn_rate"] == 0.0
    assert s["healthy"] and not s["shedding"]


def test_slo_min_samples_gates_shedding():
    clock = FakeClock()
    obj = SLObjective(latency_target_us=1.0, error_budget=0.01,
                      min_samples=10, shed_burn_rate=1.0)
    slo = SLOTracker(obj, clock=clock)
    for _ in range(9):                       # every one a breach...
        slo.record("numpy", "sum", 100.0)
    assert not slo.should_shed("numpy", "sum")   # ...but too few samples
    slo.record("numpy", "sum", 100.0)
    assert slo.should_shed("numpy", "sum")


def test_slo_objective_resolution_precedence():
    pair = SLObjective(latency_target_us=1.0)
    sub = SLObjective(latency_target_us=2.0)
    default = SLObjective(latency_target_us=3.0)
    slo = SLOTracker(objectives={("vliw-mc", "sum"): pair,
                                 "vliw-mc": sub, "default": default})
    assert slo.objective_for("vliw-mc", "sum") is pair
    assert slo.objective_for("vliw-mc", "max") is sub
    assert slo.objective_for("numpy", "sum") is default


def test_server_with_explicit_slo_sheds_load(small_spn):
    """A server constructed with an aggressive ``slo=`` objective sheds
    (Backpressure) once the burn rate crosses the threshold; the shed
    is counted and visible in stats()["slo"]."""
    server = Server(small_spn, substrates=("numpy",),
                    slo={"latency_target_us": 0.0, "error_budget": 0.5,
                         "min_samples": 3, "shed_burn_rate": 1.0})
    x = np.zeros((4, 8), dtype=np.int64)
    for _ in range(3):               # latency target 0 => every breach
        server.query(x, "joint", "numpy")
    with pytest.raises(Backpressure):
        server.query(x, "joint", "numpy")
    s = server.stats()["slo"]["numpy/sum"]
    assert s["shedding"] and s["window_events"] == 3


def test_plain_server_tracks_slo_but_never_sheds(small_spn):
    server = Server(small_spn, substrates=("numpy",))
    x = np.zeros((2, 8), dtype=np.int64)
    for _ in range(30):
        server.query(x, "joint", "numpy")
    slo = server.stats()["slo"]
    assert "numpy/sum" in slo and slo["numpy/sum"]["window_events"] == 30
    assert not slo["numpy/sum"]["shedding"]     # no objective: no shed


# --------------------------------------------------------------------------- #
# telemetry export: OpenMetrics round-trip, JSONL stream, the report
# --------------------------------------------------------------------------- #
def _fresh_registry():
    reg = obs_metrics.Registry()
    reg.counter("serve.requests").inc(7)
    reg.gauge("cache.size").set(3.5)
    h = reg.histogram("serve.latency_us.vliw-mc")
    for v in (10.0, 20.0, 30.0, 40.0):
        h.observe(v)
    return reg


def test_openmetrics_round_trip():
    reg = _fresh_registry()
    text = render_openmetrics(reg)
    assert text.endswith("# EOF\n")
    fams = parse_openmetrics(text)
    assert fams["serve_requests"]["type"] == "counter"
    assert fams["serve_requests"]["samples"] == [
        ("serve_requests_total", {}, 7.0)]
    assert fams["cache_size"]["samples"] == [("cache_size", {}, 3.5)]
    summ = fams["serve_latency_us_vliw_mc"]
    assert summ["type"] == "summary"
    by_name = {}
    for name, labels, value in summ["samples"]:
        by_name[(name, labels.get("quantile"))] = value
    h = reg.histogram("serve.latency_us.vliw-mc")
    assert by_name[("serve_latency_us_vliw_mc", "0.5")] == h.percentile(50)
    assert by_name[("serve_latency_us_vliw_mc_sum", None)] == 100.0
    assert by_name[("serve_latency_us_vliw_mc_count", None)] == 4.0


def test_openmetrics_parser_rejects_malformed():
    with pytest.raises(ValueError, match="missing # EOF"):
        parse_openmetrics("# TYPE a counter\na_total 1\n")
    with pytest.raises(ValueError, match="before TYPE"):
        parse_openmetrics("orphan 1\n# EOF\n")
    with pytest.raises(ValueError, match="after # EOF"):
        parse_openmetrics("# EOF\nstray 1\n")
    with pytest.raises(ValueError, match="malformed sample"):
        parse_openmetrics("# TYPE a gauge\na not-a-number\n# EOF\n")


def test_jsonl_exporter_stream_and_rate_limit(tmp_path):
    clock = FakeClock(100.0)
    reg = _fresh_registry()
    path = tmp_path / "telemetry.jsonl"
    exp = JsonlExporter(path, registry=reg, interval_s=5.0, clock=clock)
    assert exp.maybe_tick() is not None      # first tick always fires
    clock.advance(1.0)
    assert exp.maybe_tick() is None          # inside the interval
    clock.advance(5.0)
    reg.counter("serve.requests").inc()
    assert exp.maybe_tick() is not None
    events = JsonlExporter.read(path)
    assert [e["seq"] for e in events] == [0, 1]
    assert events[0]["metrics"]["serve.requests"] == 7
    assert events[1]["metrics"]["serve.requests"] == 8
    assert events[1]["ts"] == 106.0


def test_observatory_report_is_self_contained(small_spn, tmp_path):
    server = Server(small_spn, substrates=("numpy", "vliw-sim", "vliw-mc"),
                    cores=2)
    x = np.zeros((4, 8), dtype=np.int64)
    for name in ("numpy", "vliw-sim", "vliw-mc"):
        server.query(x, "joint", name)
    path = tmp_path / "observatory.json"
    report = write_observatory_report(path, server)
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(report))   # serializable
    assert report["version"] == 1
    assert set(report["config"]) == {"numpy", "vliw-sim", "vliw-mc"}
    subs = {a["substrate"] for a in report["attribution"]}
    assert subs == {"vliw-sim", "vliw-mc"}
    for entry in report["attribution"]:
        assert entry["bottleneck"] in CLASSES
        assert "core" in entry["table"] and "bottleneck:" in entry["table"]
        tot = entry["attribution"]["per_core"]
        for per in tot.values():
            assert sum(per.values()) == entry["attribution"]["cycles"]
    parse_openmetrics(report["openmetrics"])           # valid exposition
    assert "slo" in report and "resilience" in report
    assert observatory_report(server)["attribution"]   # re-derivable


# --------------------------------------------------------------------------- #
# bench-history regression sentinel
# --------------------------------------------------------------------------- #
def _bench_record(scale: int = 1) -> dict:
    return {
        "dataset": "nltcs", "batch": 256, "query": "marginal",
        "mc_topology": "mesh",
        "noc": {"nltcs": {"cores": 4,
                          "topologies": {"xbar": {"cycles": 32 * scale},
                                         "mesh": {"cycles": 33 * scale}}}},
        "multicore_scaling": {"nltcs": {
            "topology": "mesh", "single_core_cycles": 51 * scale,
            "cores": {"2": {"cycles": 36 * scale},
                      "4": {"cycles": 33 * scale}}}},
        "autotune": {"budget": 16, "max_cores": 4,
                     "datasets": {"nltcs":
                                  {"tuned_cycles_per_eval": 15.0 * scale}}},
        "vliw_fastsim": {"cycles": 51 * scale},
    }


def test_history_fingerprint_and_metrics_deterministic():
    from benchmarks.history import deterministic_metrics, run_fingerprint
    a, b = _bench_record(), _bench_record()
    assert run_fingerprint(a) == run_fingerprint(b)
    assert len(run_fingerprint(a)) == 16
    # metric VALUES don't move the fingerprint; workload knobs do
    assert run_fingerprint(_bench_record(scale=2)) == run_fingerprint(a)
    other = _bench_record()
    other["dataset"] = "kdd"
    assert run_fingerprint(other) != run_fingerprint(a)
    m = deterministic_metrics(a)
    assert m == {"noc.nltcs.mesh.cycles": 33, "noc.nltcs.xbar.cycles": 32,
                 "scaling.nltcs.single_core.cycles": 51,
                 "scaling.nltcs.c2.cycles": 36,
                 "scaling.nltcs.c4.cycles": 33,
                 "autotune.nltcs.tuned_cycles_per_eval": 15.0,
                 "vliw_sim.cycles": 51}


def test_history_append_and_exact_sentinel(tmp_path):
    from benchmarks.history import (append_run, best_prior, load_history,
                                    run_fingerprint, sentinel_compare)
    path = str(tmp_path / "BENCH_history.jsonl")
    assert load_history(path) == []                     # missing file ok
    rec = _bench_record()
    assert sentinel_compare(rec, []) == []              # empty history ok
    e1 = append_run(path, rec, sha="aaaa111", now=1000.0)
    assert e1["sha"] == "aaaa111" and e1["time"] == 1000.0
    history = load_history(path)
    assert history == [e1]                              # round-trips
    # identical run: exact equality passes
    assert sentinel_compare(rec, history) == []
    # strictly better run passes and becomes the new best
    better = _bench_record()
    better["noc"]["nltcs"]["topologies"]["mesh"]["cycles"] = 30
    assert sentinel_compare(better, history) == []
    append_run(path, better, sha="bbbb222", now=2000.0)
    history = load_history(path)
    best = best_prior(history, run_fingerprint(rec))
    assert best["noc.nltcs.mesh.cycles"] == (30, "bbbb222")
    assert best["noc.nltcs.xbar.cycles"] == (32, "aaaa111")
    # +1 cycle over the best prior: the sentinel holds counts EXACTLY
    worse = _bench_record()
    worse["noc"]["nltcs"]["topologies"]["mesh"]["cycles"] = 31
    failures = sentinel_compare(worse, history)
    assert len(failures) == 1
    assert "noc.nltcs.mesh.cycles" in failures[0]
    assert "bbbb222" in failures[0]
    # incommensurable fingerprint: never compared, never fails
    other = _bench_record(scale=50)
    other["dataset"] = "kdd"
    other["noc"] = {"kdd": rec["noc"]["nltcs"]}
    assert sentinel_compare(other, history) == []


def test_history_cli_check_gate(tmp_path):
    from benchmarks.history import load_history, main
    rec_path = tmp_path / "BENCH_serve.json"
    hist_path = tmp_path / "BENCH_history.jsonl"
    rec_path.write_text(json.dumps(_bench_record()))
    assert main(["--record", str(rec_path),
                 "--history", str(hist_path)]) == 0
    assert len(load_history(str(hist_path))) == 1
    worse = _bench_record()
    worse["vliw_fastsim"]["cycles"] = 52
    rec_path.write_text(json.dumps(worse))
    # without --check a regression warns but exits 0 (and appends)
    assert main(["--record", str(rec_path),
                 "--history", str(hist_path)]) == 0
    assert len(load_history(str(hist_path))) == 2
    # with --check the same regression fails the process, no append
    assert main(["--record", str(rec_path), "--history", str(hist_path),
                 "--check", "--no-append"]) == 2
    assert len(load_history(str(hist_path))) == 2


# --------------------------------------------------------------------------- #
# Histogram.percentile: edge cases + numpy property test
# --------------------------------------------------------------------------- #
def _hist(values):
    h = obs_metrics.Registry().histogram("h")
    for v in values:
        h.observe(v)
    return h


def test_percentile_edge_cases():
    h = _hist([])
    assert math.isnan(h.percentile(50))
    for bad in (-0.001, 100.001, -5, 200):
        with pytest.raises(ValueError, match="percentile"):
            h.percentile(bad)
    one = _hist([42.0])
    assert one.percentile(0) == one.percentile(50) \
        == one.percentile(100) == 42.0
    two = _hist([1.0, 3.0])
    assert two.percentile(0) == 1.0 and two.percentile(100) == 3.0
    assert two.percentile(50) == 2.0


@settings(max_examples=60, deadline=None)
@given(values=st.lists(st.floats(min_value=-1e6, max_value=1e6,
                                 allow_nan=False, allow_infinity=False),
                       min_size=1, max_size=50),
       p=st.floats(min_value=0.0, max_value=100.0))
def test_percentile_matches_numpy(values, p):
    h = _hist(values)
    got = h.percentile(p)
    want = float(np.percentile(np.asarray(values, dtype=float), p))
    assert got == pytest.approx(want, rel=1e-9, abs=1e-9)
    assert h.percentile(0) == min(values)
    assert h.percentile(100) == max(values)


# --------------------------------------------------------------------------- #
# stats() deep-copy isolation
# --------------------------------------------------------------------------- #
def test_stats_snapshot_is_deep_copied(small_spn):
    server = Server(small_spn, substrates=("numpy",))
    server.query(np.zeros((2, 8), dtype=np.int64), "joint", "numpy")
    server.resilience.record("probe", detail="original")
    s1 = server.stats()
    # vandalize every mutable corner of the snapshot
    s1["metrics"].clear()
    s1["slo"].clear()
    s1["resilience"]["history"][0]["detail"] = "vandalized"
    s1["resilience"]["history"].append({"kind": "fake"})
    s2 = server.stats()
    assert s2["metrics"]          # live registry untouched
    assert s2["slo"]
    hist = s2["resilience"]["history"]
    assert [h["kind"] for h in hist] == ["probe"]
    assert hist[0]["detail"] == "original"
    # and the manager's own history object was never aliased out
    assert server.resilience.history[0]["detail"] == "original"


# --------------------------------------------------------------------------- #
# tracing: split-retry trace ids + partial flush on a crashed run
# --------------------------------------------------------------------------- #
def test_split_retry_spans_keep_original_trace_ids():
    calls = {"n": 0}

    def execute(rows):
        calls["n"] += 1
        if rows.shape[0] > 1:
            raise RuntimeError("coalesced batch dies")
        return rows[:, 0]

    tracer = trace.install()
    try:
        b = MicroBatcher(execute, tile=1, split_retry=True)
        p1 = b.submit(np.ones((1, 2), np.float32))
        p2 = b.submit(np.ones((1, 2), np.float32) * 2)
        p1.trace_id, p2.trace_id = 11, 22
        b.flush()
        assert p1.result() == [1.0] and p2.result() == [2.0]
    finally:
        trace.uninstall()
    flushes = tracer.spans("batch.flush")
    coalesced = [e for e in flushes if not e["args"].get("split_retry")]
    retried = [e for e in flushes if e["args"].get("split_retry")]
    # the failed coalesced flush linked both members...
    assert len(coalesced) == 1
    assert coalesced[0]["args"]["trace_ids"] == [11, 22]
    assert coalesced[0]["args"]["requests"] == 2
    # ...and each retried member keeps its ORIGINAL id — never a fresh
    # one — so the re-execution still links back to its request
    assert sorted(e["args"]["trace_ids"][0] for e in retried) == [11, 22]
    assert all(e["args"]["requests"] == 1 for e in retried)
    assert all(not e["error"] for e in retried)
    assert tracer.spans("batch.split_retry")   # the retry is marked


def test_serve_trace_partial_flush_on_crash(tmp_path, monkeypatch):
    """A serve run that dies mid-flight still writes a complete, valid
    Chrome trace file (marked PARTIAL on stdout) and uninstalls the
    tracer — crashed runs leave evidence, not corruption."""
    from repro.launch import serve as serve_mod

    def doomed(obs, *args, **kwargs):
        with obs.trace.span("serve.request", {"doomed": True}, root=True):
            pass
        raise RuntimeError("mid-flight crash")

    monkeypatch.setattr(serve_mod, "_serve_spn_run", doomed)
    path = tmp_path / "partial.json"
    with pytest.raises(RuntimeError, match="mid-flight crash"):
        serve_mod.serve_spn("nltcs", 8, 1, substrate="numpy",
                            trace_path=str(path))
    assert not trace.active()        # tracer uninstalled despite the crash
    doc = json.loads(path.read_text())   # valid JSON, complete structure
    names = [e.get("name") for e in doc["traceEvents"]]
    assert "serve.request" in names


# --------------------------------------------------------------------------- #
# the attribution-guided autotune prior
# --------------------------------------------------------------------------- #
def test_autotune_prior_guides_the_search(nltcs_prog):
    from repro.core.autotune import tune_program
    res = tune_program(nltcs_prog, PTREE, max_cores=4, budget=8,
                       use_cache=False)
    assert res.prior is not None
    assert res.prior["bottleneck"] in CLASSES
    assert res.prior["group"] == GROUP_OF_CLASS[res.prior["bottleneck"]]
    assert abs(sum(res.prior["fractions"].values()) - 1.0) < 1e-5
    assert res.prior["roofline_bound"] in ("compute", "communication")
    # guided candidates were actually evaluated right after the default
    tried = [fp for fp, _, _ in res.trials]
    assert res.guided and set(res.guided) <= set(tried)
    assert tried[1: 1 + len(res.guided)] == res.guided
    if res.guided_win:
        assert res.config.fingerprint() in res.guided
    # and the prior surfaces through the serving stats
    server = Server(prog=nltcs_prog, substrates=("vliw-mc",), cores=4,
                    autotune="budget=8")
    server.query(np.zeros((4, 16), dtype=np.int64), "marginal", "vliw-mc")
    tune = server.stats()["autotune"]["sum/vliw-mc"]
    assert tune["prior"]["bottleneck"] in CLASSES
