"""Integrity of the committed dry-run artifacts (experiments/dryrun).

These JSONs are the §Dry-run/§Roofline deliverable — every applicable
(arch × shape × mesh) cell must exist with status ok (or a policy skip),
with coherent roofline terms. Skipped automatically if the artifacts
haven't been generated in this checkout.
"""
import json
import os

import pytest

from repro.configs.base import ARCH_IDS, SHAPES, applicable_shapes, get_config

DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

pytestmark = pytest.mark.skipif(
    not os.path.isdir(DIR), reason="dry-run artifacts not generated")


def _load(arch, shape, mesh):
    p = os.path.join(DIR, f"{arch}_{shape}_{mesh}.json")
    assert os.path.exists(p), f"missing cell artifact {p}"
    with open(p) as f:
        return json.load(f)


@pytest.mark.parametrize("mesh", ["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_all_cells_present_and_ok(arch, mesh):
    cfg = get_config(arch)
    for shape in SHAPES:
        rec = _load(arch, shape, mesh)
        if shape in applicable_shapes(cfg):
            assert rec["status"] == "ok", (arch, shape, mesh, rec.get("error"))
            roof = rec["roofline"]
            assert roof["flops"] > 0 and roof["hbm_bytes"] > 0
            assert roof["bottleneck"] in ("compute", "memory", "collective")
            assert rec["model_flops"] > 0
        else:
            assert rec["status"] == "skipped"


def test_long_context_only_subquadratic():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        rec = _load(arch, "long_500k", "single")
        if cfg.sub_quadratic and cfg.has_decoder:
            assert rec["status"] == "ok", arch
        else:
            assert rec["status"] == "skipped", arch


def test_multi_pod_scales_terms():
    """Pure-DP pod axis: per-chip compute term should not grow 2× when
    doubling chips (it should shrink or stay equal for train cells)."""
    for arch in ("qwen2-0.5b", "glm4-9b"):
        s = _load(arch, "train_4k", "single")["roofline"]
        m = _load(arch, "train_4k", "multi")["roofline"]
        assert m["t_compute_s"] <= s["t_compute_s"] * 1.1
