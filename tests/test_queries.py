"""Query engine: cross-substrate agreement for marginal / MPE / sampling,
decoder equivalence, sampler statistics, and evidence-mask helpers."""
import itertools

import numpy as np
import pytest

from repro.core import executors, program
from repro.core.learn import random_spn
from repro.core.spn import SPNBuilder, normalize_weights
from repro.queries import (QueryEngine, evidence_array, mask_vars,
                           merge_evidence, mpe_backtrace, mpe_decode_grad,
                           random_mask, sample_ancestral_jax,
                           sample_ancestral_numpy)

BACKENDS = ("numpy", "leveled", "kernel", "sim")


@pytest.fixture(scope="module")
def engine(nltcs_spn):
    return QueryEngine(nltcs_spn)


@pytest.fixture(scope="module")
def small_engine(small_spn):
    return QueryEngine(normalize_weights(small_spn))


@pytest.fixture(scope="module")
def bernoulli_engine():
    """Fully factorized (selective) SPN: max-product MPE is exact."""
    b = SPNBuilder()
    probs = [0.9, 0.2, 0.6, 0.35, 0.55]   # no 0.5: exact argmax ties would
    # make the brute-force comparison ambiguous
    leaves = [b.sum([b.indicator(v, 1), b.indicator(v, 0)], [p, 1.0 - p])
              for v, p in enumerate(probs)]
    return QueryEngine(b.build(b.product(leaves))), probs


def _masked_evidence(num_vars, n=6, seed=0, frac=0.5):
    rng = np.random.default_rng(seed)
    return random_mask(rng.integers(0, 2, (n, num_vars)), frac, seed=seed)


# ---------------------------------------------------------------------------
# max-product program structure
# ---------------------------------------------------------------------------
def test_to_max_product_structure(nltcs_prog):
    mp = program.to_max_product(nltcs_prog)
    mp.validate()
    assert (mp.opcode != program.OP_SUM).all()
    assert ((mp.opcode == program.OP_MAX).sum()
            == (nltcs_prog.opcode == program.OP_SUM).sum())
    assert (mp.opcode[nltcs_prog.opcode == program.OP_PROD]
            == program.OP_PROD).all()
    # skeleton shared: same slots, levels, operands
    np.testing.assert_array_equal(mp.b, nltcs_prog.b)
    np.testing.assert_array_equal(mp.c, nltcs_prog.c)
    np.testing.assert_array_equal(mp.level_offsets, nltcs_prog.level_offsets)


# ---------------------------------------------------------------------------
# marginal queries
# ---------------------------------------------------------------------------
def test_marginal_cross_substrate(engine):
    X = _masked_evidence(engine.num_vars)
    ref = engine.marginal(X, "numpy")
    for b in BACKENDS[1:]:
        np.testing.assert_allclose(engine.marginal(X, b), ref, atol=1e-4,
                                   err_msg=b)


def test_full_evidence_marginal_equals_joint(engine, nltcs_data):
    """Regression: with no -1 entries, marginal degenerates to the joint."""
    X = nltcs_data[:16]
    np.testing.assert_allclose(engine.marginal(X, "leveled"),
                               engine.joint(X, "leveled"), rtol=0)


def test_marginal_sums_over_hidden_var(small_engine):
    """p(e) == Σ_v p(e, q=v) — the defining property of marginalization."""
    rng = np.random.default_rng(5)
    X = rng.integers(0, 2, (4, 8))
    Xm = mask_vars(X, [2])
    pe = np.exp(small_engine.marginal(Xm, "numpy"))
    total = sum(np.exp(small_engine.marginal(
        merge_evidence(Xm, evidence_array(8, {2: v}, batch=4)), "numpy"))
        for v in (0, 1))
    np.testing.assert_allclose(pe, total, rtol=1e-9)


def test_all_marginalized_is_partition_function(engine):
    x = np.full((1, engine.num_vars), -1, np.int64)
    for b in BACKENDS:
        assert abs(float(engine.marginal(x, b)[0])) < 1e-4, b


def test_conditional_bayes_consistency(small_engine):
    """p(q|e)·p(e) == p(q,e) and conditionals normalize over q."""
    e = evidence_array(8, {1: 1, 4: 0}, batch=1)
    probs = [float(np.exp(small_engine.conditional(
        evidence_array(8, {0: v}), e, "leveled"))[0]) for v in (0, 1)]
    assert abs(sum(probs) - 1.0) < 1e-4


# ---------------------------------------------------------------------------
# MPE queries
# ---------------------------------------------------------------------------
def test_mpe_cross_substrate(engine):
    X = _masked_evidence(engine.num_vars)
    ref = engine.mpe(X, "numpy")
    for b in BACKENDS[1:]:
        r = engine.mpe(X, b)
        np.testing.assert_allclose(r.log_value, ref.log_value, atol=1e-4,
                                   err_msg=b)
        np.testing.assert_array_equal(r.assignment, ref.assignment, err_msg=b)


def test_mpe_decoders_agree(engine):
    X = _masked_evidence(engine.num_vars, n=12, seed=3)
    bt, _ = mpe_backtrace(engine.max_prog, X)
    gd = mpe_decode_grad(engine.max_prog, X)
    np.testing.assert_array_equal(bt, gd)


def test_mpe_invariants(engine):
    """Decoded assignment respects evidence; its true probability
    upper-bounds the max-product value (best-tree ≤ full sum)."""
    X = _masked_evidence(engine.num_vars, seed=9)
    r = engine.mpe(X, "numpy")
    assert np.all((r.assignment == X) | (X < 0))
    assert np.all((r.assignment >= 0) & (r.assignment <= 1))
    joint = engine.joint(r.assignment, "numpy")
    assert np.all(joint >= r.log_value - 1e-9)


def test_mpe_exact_on_selective_spn(bernoulli_engine):
    """Fully factorized SPN: MPE == per-variable argmax, verified by
    brute force over all 2^5 states on every substrate."""
    eng, probs = bernoulli_engine
    V = len(probs)
    states = np.array(list(itertools.product([0, 1], repeat=V)))
    joints = eng.joint(states, "numpy")
    best = states[int(np.argmax(joints))]
    free = np.full((1, V), -1, np.int64)
    for b in BACKENDS:
        r = eng.mpe(free, b)
        np.testing.assert_array_equal(r.assignment[0], best, err_msg=b)
        np.testing.assert_allclose(r.log_value[0], joints.max(), atol=1e-5,
                                   err_msg=b)


def test_mpe_with_evidence_flips_argmax(bernoulli_engine):
    """Observing a variable overrides its unconstrained argmax."""
    eng, probs = bernoulli_engine
    anti = {v: int(p < 0.5) for v, p in enumerate(probs)}  # least likely
    x = evidence_array(len(probs), anti)
    r = eng.mpe(x, "numpy")
    np.testing.assert_array_equal(r.assignment[0],
                                  [anti[v] for v in range(len(probs))])


# ---------------------------------------------------------------------------
# sampling queries
# ---------------------------------------------------------------------------
def test_sampler_substrates_bit_identical(engine):
    a = sample_ancestral_numpy(engine.spn, 257, seed=11)
    b = sample_ancestral_jax(engine.spn, 257, seed=11)
    np.testing.assert_array_equal(a, b)


def test_samples_are_complete_binary(engine):
    s = engine.sample(64, seed=2, backend="leveled")
    assert s.samples.shape == (64, engine.num_vars)
    assert set(np.unique(s.samples)) <= {0, 1}       # every var assigned
    assert np.all(np.isfinite(s.log_prob))


def test_sampler_statistics_match_marginals(small_engine):
    """Empirical univariate marginals of 4000 draws track exact ones."""
    n = 4000
    s = small_engine.sample(n, seed=0, backend="numpy")
    emp = s.samples.mean(0)
    exact = np.array([float(np.exp(small_engine.marginal(
        evidence_array(8, {v: 1}), "numpy"))[0]) for v in range(8)])
    # ~4 sigma of a Bernoulli mean at n=4000
    assert np.abs(emp - exact).max() < 4.0 * 0.5 / np.sqrt(n) + 1e-3


def test_sample_scoring_cross_substrate(engine):
    draws = {b: engine.sample(50, seed=4, backend=b) for b in BACKENDS}
    ref = draws["numpy"]
    for b in BACKENDS[1:]:
        np.testing.assert_array_equal(draws[b].samples, ref.samples,
                                      err_msg=b)
        np.testing.assert_allclose(draws[b].log_prob, ref.log_prob,
                                   atol=1e-4, err_msg=b)


def test_sampler_respects_degenerate_weights():
    """A (1.0, 0.0) mixture must never pick the zero branch."""
    b = SPNBuilder()
    i1, i0 = b.indicator(0, 1), b.indicator(0, 0)
    spn = b.build(b.sum([i1, i0], [1.0, 0.0]))
    s = sample_ancestral_numpy(spn, 500, seed=0)
    assert (s == 1).all()
    np.testing.assert_array_equal(sample_ancestral_jax(spn, 500, seed=0), s)


# ---------------------------------------------------------------------------
# evidence helpers
# ---------------------------------------------------------------------------
def test_evidence_helpers():
    e = evidence_array(6, {0: 1, 3: 0}, batch=2)
    assert e.shape == (2, 6) and e[0, 0] == 1 and e[1, 3] == 0
    assert (e[:, [1, 2, 4, 5]] == -1).all()
    with pytest.raises(ValueError):
        evidence_array(6, {7: 1})
    with pytest.raises(ValueError):
        merge_evidence(evidence_array(6, {0: 1}), evidence_array(6, {0: 0}))
    m = merge_evidence(evidence_array(6, {0: 1}), evidence_array(6, {5: 0}))
    assert m[0, 0] == 1 and m[0, 5] == 0
    masked = mask_vars(e, [0])
    assert (masked[:, 0] == -1).all() and e[0, 0] == 1  # copy semantics


def test_joint_rejects_partial_evidence(engine):
    with pytest.raises(ValueError):
        engine.joint(np.full((1, engine.num_vars), -1), "numpy")
