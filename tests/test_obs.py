"""Observability layer (`repro.obs`): tracing, metrics, cycle timelines.

Covers the three pillars end to end:

- span API invariants: nesting (parent/child ids, time containment),
  per-request trace ids, lazy attrs, disabled no-op fast path,
- error spans: an exception inside a traced section is *recorded* (type
  + message in the attrs), never silently dropped — including through
  ``runtime.Server`` execute and ``runtime.fault.run_with_restarts``,
- metrics registry: counter/gauge semantics, histogram percentile
  correctness against ``np.percentile``, disabled no-ops, dump formats,
- trace-id propagation: ``Server.submit`` mints one trace id per
  request and the coalesced ``batch.flush`` span links them all,
- Chrome trace JSON schema validity (perfetto-loadable shape),
- cycle timelines: per-core interval sums equal the lockstep sim's
  global cycle count exactly, and match the committed
  ``tests/golden_cycles.json`` fixture.
"""
import json
import pathlib

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import obs
from repro.core import learn, multicore as mc, program
from repro.core.multicore.comm import named_interconnect
from repro.core.processor.config import PTREE
from repro.data import spn_datasets
from repro.obs import metrics, timeline, trace
from repro.runtime import Server
from repro.runtime.fault import (RestartPolicy, TrainingAborted,
                                 run_with_restarts)

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden_cycles.json"


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts with tracing off and an empty registry."""
    trace.uninstall()
    metrics.REGISTRY.reset()
    metrics.REGISTRY.enabled = True
    yield
    trace.uninstall()
    metrics.REGISTRY.reset()
    metrics.REGISTRY.enabled = True


# ---------------------------------------------------------------------------
# tracing: spans, nesting, trace ids, disabled path
# ---------------------------------------------------------------------------
def test_disabled_span_is_shared_noop():
    assert not trace.active()
    s1 = trace.span("a", {"x": 1})
    s2 = trace.span("b")
    assert s1 is s2            # one cached object, no per-call allocation
    with s1 as sp:
        sp.set("k", "v")       # no-op, no error
    assert sp.trace_id == 0


def test_lazy_attrs_not_evaluated_when_disabled():
    calls = []

    def attrs():
        calls.append(1)
        return {"x": 1}

    with trace.span("a", attrs):
        pass
    assert not calls           # disabled: the callable was never invoked
    tracer = trace.install()
    with trace.span("a", attrs):
        pass
    assert calls == [1]
    assert tracer.spans("a")[0]["args"]["x"] == 1


def test_span_nesting_parent_child_and_time_containment():
    tracer = trace.install()
    with trace.span("outer") as out_sp:
        with trace.span("inner") as in_sp:
            pass
    outer, = tracer.spans("outer")
    inner, = tracer.spans("inner")
    assert inner["parent_id"] == outer["span_id"]
    assert inner["trace_id"] == outer["trace_id"]
    assert outer["ts_us"] <= inner["ts_us"]
    assert (inner["ts_us"] + inner["dur_us"]
            <= outer["ts_us"] + outer["dur_us"] + 1e-6)
    assert in_sp.parent_id == out_sp.span_id


@settings(max_examples=20, deadline=None)
@given(depth=st.integers(1, 8), width=st.integers(1, 4))
def test_span_ordering_invariants(depth, width):
    """Random nest shapes: ids unique, children contained, stack clean."""
    tracer = trace.Tracer()
    trace.install(tracer)
    try:
        def nest(d):
            for _ in range(width):
                with trace.span(f"d{d}"):
                    if d > 1:
                        nest(d - 1)
        nest(depth)
    finally:
        trace.uninstall()
    events = tracer.events
    assert len(events) == sum(width ** k for k in range(1, depth + 1))
    ids = [e["span_id"] for e in events]
    assert len(set(ids)) == len(ids)
    by_id = {e["span_id"]: e for e in events}
    for e in events:
        if e["parent_id"]:
            p = by_id[e["parent_id"]]
            assert e["trace_id"] == p["trace_id"]
            assert p["ts_us"] - 1e-6 <= e["ts_us"]
            assert (e["ts_us"] + e["dur_us"]
                    <= p["ts_us"] + p["dur_us"] + 1e-6)
    assert tracer._stack() == []      # balanced enter/exit


def test_root_spans_get_distinct_trace_ids():
    tracer = trace.install()
    with trace.span("r1", root=True) as a:
        pass
    with trace.span("r2", root=True) as b:
        pass
    assert a.trace_id != b.trace_id
    assert {e["trace_id"] for e in tracer.events} == {a.trace_id, b.trace_id}


def test_error_span_records_exception_and_propagates():
    tracer = trace.install()
    with pytest.raises(ValueError, match="boom"):
        with trace.span("will_fail", {"k": 1}):
            raise ValueError("boom")
    rec, = tracer.spans("will_fail")
    assert rec["error"] is True
    assert rec["args"]["error"] == "ValueError"
    assert "boom" in rec["args"]["message"]
    assert rec["args"]["k"] == 1      # original attrs survive the error


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_gauge_semantics():
    metrics.counter("c").inc()
    metrics.counter("c").inc(4)
    metrics.gauge("g").set(2.5)
    snap = metrics.snapshot()
    assert snap["c"] == 5
    assert snap["g"] == 2.5
    with pytest.raises(TypeError):
        metrics.gauge("c")            # name/type collision is loud


def test_histogram_percentiles_match_numpy():
    h = metrics.histogram("lat")
    rng = np.random.default_rng(7)
    xs = rng.exponential(100.0, 500)
    for x in xs:
        h.observe(x)
    for p in (50, 90, 95, 99):
        assert h.percentile(p) == pytest.approx(
            np.percentile(xs, p, method="linear"), rel=1e-9)
    s = h.summary()
    assert s["count"] == 500
    assert s["min"] == pytest.approx(xs.min())
    assert s["max"] == pytest.approx(xs.max())
    assert s["mean"] == pytest.approx(xs.mean(), rel=1e-6)


def test_histogram_ring_keeps_newest_samples():
    h = metrics.Histogram("h", metrics.REGISTRY, max_samples=16)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100             # running totals cover the stream
    assert h.percentile(0) >= 84.0    # ring holds only the newest 16


def test_registry_disabled_is_noop():
    metrics.REGISTRY.enabled = False
    metrics.counter("c").inc()
    metrics.gauge("g").set(9)
    metrics.histogram("h").observe(1.0)
    snap = metrics.snapshot()
    assert snap["c"] == 0 and snap["g"] == 0.0
    assert snap["h"] == {"count": 0}


def test_dump_formats():
    metrics.counter("serve.requests").inc(3)
    metrics.histogram("lat").observe(10.0)
    text = metrics.dump()
    assert "counter serve.requests" in text and "hist" in text
    assert json.loads(metrics.dump("json"))["serve.requests"] == 3
    with pytest.raises(ValueError):
        metrics.dump("yaml")


# ---------------------------------------------------------------------------
# server integration: trace-id propagation, latency metrics, error spans
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def obs_server(small_spn):
    return Server(small_spn, substrates=("numpy", "vliw-sim"))


def test_trace_id_propagates_through_server(obs_server, small_spn):
    tracer = trace.install()
    X = np.random.default_rng(0).integers(0, 2, (4, small_spn.num_vars))
    p1 = obs_server.submit(X, "joint", "numpy")
    p2 = obs_server.submit(X, "joint", "numpy")
    assert p1.trace_id and p2.trace_id and p1.trace_id != p2.trace_id
    obs_server.flush()
    flush = tracer.spans("batch.flush")[-1]
    assert set(flush["args"]["trace_ids"]) >= {p1.trace_id, p2.trace_id}
    reqs = tracer.spans("serve.request")
    assert {r["trace_id"] for r in reqs} >= {p1.trace_id, p2.trace_id}
    execs = tracer.spans("exec.numpy")
    assert execs and execs[-1]["args"]["rows"] >= 8   # coalesced (+ padding)


def test_server_latency_metrics_and_stats_snapshot(obs_server, small_spn):
    X = np.random.default_rng(1).integers(0, 2, (4, small_spn.num_vars))
    obs_server.query(X, "joint", "vliw-sim")
    stats = obs_server.stats()
    snap = stats["metrics"]
    assert snap["serve.requests"] >= 1
    assert snap["serve.latency_us.vliw-sim"]["count"] >= 1
    assert snap["serve.latency_us.vliw-sim"]["p50"] > 0
    # backward-compatible keys all still present
    for key in ("cache", "compiles", "padded_rows", "batchers", "multicore"):
        assert key in stats


def test_substrate_failure_records_error_span(small_spn):
    """Regression: a substrate failure inside a traced request must emit
    an error span naming the exception type, not silently drop it."""
    server = Server(small_spn, substrates=("numpy",))
    tracer = trace.install()
    X = np.random.default_rng(2).integers(0, 2, (2, small_spn.num_vars))
    server.query(X, "joint", "numpy")                 # build the batcher

    def exploding_execute(artifact, leaves):
        raise RuntimeError("substrate hardware fault")

    server.substrates["numpy"].execute = exploding_execute
    with pytest.raises(RuntimeError, match="hardware fault"):
        server.query(X, "joint", "numpy")
    errors = [e for e in tracer.spans("exec.numpy") if e["error"]]
    assert errors, "execute failure left no error span"
    assert errors[-1]["args"]["error"] == "RuntimeError"
    assert "hardware fault" in errors[-1]["args"]["message"]
    flush_errors = [e for e in tracer.spans("batch.flush") if e["error"]]
    assert flush_errors, "flush span dropped instead of marked errored"
    assert metrics.snapshot()["serve.errors"] >= 1


def test_fault_restart_chains_cause_and_counts():
    tracer = trace.install()

    def run(_state):
        raise OSError("flaky HBM")

    with pytest.raises(TrainingAborted) as ei:
        run_with_restarts(lambda: {}, lambda: None, run,
                          RestartPolicy(max_failures=2))
    assert isinstance(ei.value.__cause__, OSError)    # honest chaining
    assert "flaky HBM" in str(ei.value)
    attempts = [e for e in tracer.spans("fault.attempt") if e["error"]]
    assert len(attempts) == 3
    assert attempts[0]["args"]["error"] == "OSError"
    assert metrics.snapshot()["fault.restarts"] == 3


# ---------------------------------------------------------------------------
# Chrome trace export schema
# ---------------------------------------------------------------------------
def test_chrome_trace_schema(tmp_path, obs_server, small_spn):
    tracer = trace.install()
    X = np.random.default_rng(3).integers(0, 2, (4, small_spn.num_vars))
    obs_server.query(X, "joint", "numpy")
    out = tmp_path / "trace.json"
    n = trace.write_chrome_trace(str(out), tracer)
    doc = json.loads(out.read_text())                 # valid JSON
    events = doc["traceEvents"]
    assert len(events) == n and doc["displayTimeUnit"] == "ms"
    assert any(e["ph"] == "M" and e["name"] == "process_name"
               for e in events)
    for e in events:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert isinstance(e["ts"], float) and e["dur"] > 0
            assert e["args"]["trace_id"] >= 0
    spans = [e for e in events if e["ph"] == "X"]
    assert any(e["name"] == "serve.request" for e in spans)


# ---------------------------------------------------------------------------
# cycle timelines
# ---------------------------------------------------------------------------
def _mcp(prog, cores, topology="xbar"):
    return mc.compile_multicore(prog, PTREE, cores,
                                named_interconnect(topology))


def test_timeline_covers_every_core_cycle(nltcs_prog):
    mcp = _mcp(nltcs_prog, 4, "mesh")
    rec, res = timeline.record_multicore(mcp)
    assert rec.cycles == res.cycles == mcp.meta["cycles"]
    totals = rec.core_totals()
    assert sorted(totals) == [cp.core for cp in mcp.cores]
    for core, tot in totals.items():
        assert sum(tot.values()) == res.cycles     # exact coverage
        ivs = rec.intervals(core)
        assert ivs[0][1] == 0 and ivs[-1][2] == res.cycles
        for (s0, a0, b0), (s1, a1, b1) in zip(ivs, ivs[1:]):
            assert b0 == a1 and s0 != s1           # contiguous RLE
    # state totals agree with the sim's own accounting
    for cp, stalls, idle in zip(mcp.cores, res.stall_cycles,
                                res.barrier_idle):
        assert totals[cp.core]["stall"] == stalls
        assert totals[cp.core]["barrier"] == idle
        assert totals[cp.core]["issue"] == len(cp.vprog.instrs)


def test_timeline_matches_golden_cycles():
    """The exported timeline's cycle span equals the committed golden
    lockstep counts exactly (same learn config as tests/test_noc.py)."""
    golden = json.loads(GOLDEN_PATH.read_text())
    cfg = golden["learn"]
    X = spn_datasets.load("nltcs", "train", cfg["rows"])
    spn = learn.learn_spn(X, min_instances=cfg["min_instances"],
                          seed=cfg["seed"])
    prog = program.lower(spn)
    for cores in (2, 4):
        for topo in ("xbar", "mesh"):
            want = golden["cycles"]["nltcs"][str(cores)][topo]
            rec, res = timeline.record_multicore(_mcp(prog, cores, topo))
            assert rec.cycles == want == res.cycles
            assert all(sum(t.values()) == want
                       for t in rec.core_totals().values())


def test_timeline_chrome_events_have_per_core_tracks(nltcs_prog):
    mcp = _mcp(nltcs_prog, 4, "mesh")
    rec, res = timeline.record_multicore(mcp)
    events = rec.to_chrome_events(pid=2)
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {f"core {cp.core}" for cp in mcp.cores} <= names
    cyc = [e for e in events if e.get("cat") == "cycles"]
    assert cyc and all(e["pid"] == 2 for e in cyc)
    # per-core X events sum to cycles per track
    per_core: dict = {}
    for e in cyc:
        per_core[e["tid"]] = per_core.get(e["tid"], 0) + e["dur"]
    assert all(v == res.cycles for v in per_core.values())
    # comm markers + link occupancy present on a contended mesh run
    if mcp.plan.rows:
        assert any(e.get("cat") == "comm" for e in events)
        assert any(e.get("cat") == "noc" for e in events)


def test_timeline_recording_does_not_change_cycles(nltcs_prog):
    """The recorder must be a pure observer: identical cycle counts and
    root values with and without it."""
    from repro.core.multicore.sim import simulate_multicore

    mcp = _mcp(nltcs_prog, 4, "torus")
    leaves = np.ones((3, nltcs_prog.m_ind), np.float32)
    plain = simulate_multicore(mcp, leaves)
    rec = timeline.TimelineRecorder()
    observed = simulate_multicore(mcp, leaves, recorder=rec)
    assert plain.cycles == observed.cycles == rec.cycles
    assert np.array_equal(plain.root_values, observed.root_values)
    assert plain.stall_cycles == observed.stall_cycles
