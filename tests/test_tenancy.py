"""Multi-tenant serving fabric: QoS-weighted core apportionment, the
model registry, co-residency on the vliw-mc mesh (disjoint core sets),
async continuous batching (age-deadline pump, pump thread), per-tenant
stats keying without collisions, and the serving-time rebalancer."""
import threading
import time

import numpy as np
import pytest

from repro.core.learn import random_spn
from repro.queries import random_mask
from repro.runtime import (Server, Tenant, allocate_cores, plan_rebalance,
                           verify_parity)
from repro.runtime.tenancy import (ModelRegistry, as_tenant,
                                   blocks_from_counts)

SUBSTRATES = ("numpy", "vliw-sim", "vliw-mc")


def _spn(num_vars, seed):
    return random_spn(num_vars, depth=2, num_sums=2, repetitions=2,
                      seed=seed)


def _evidence(num_vars, n=12, seed=0):
    rng = np.random.default_rng(seed)
    return random_mask(rng.integers(0, 2, (n, num_vars)), 0.4, seed=seed)


@pytest.fixture(scope="module")
def duo():
    """One server, two tenant SPNs, co-scheduled on an 8-core mesh."""
    return Server(tenants={"alpha": _spn(8, 1), "beta": _spn(10, 2)},
                  substrates=SUBSTRATES, cores=8, topology="mesh")


# ---------------------------------------------------------------------------
# core apportionment (pure)
# ---------------------------------------------------------------------------
def test_allocate_cores_equal_weights_split_evenly():
    assert allocate_cores({"a": 1.0, "b": 1.0}, 8) == \
        {"a": (0, 1, 2, 3), "b": (4, 5, 6, 7)}


def test_allocate_cores_qos_weight_skews_shares():
    alloc = allocate_cores({"a": 1.0, "b": 3.0}, 8)
    assert len(alloc["a"]) == 2 and len(alloc["b"]) == 6
    # largest remainder: quotas 2.67/5.33 -> the extra core goes to a
    alloc = allocate_cores({"a": 1.0, "b": 2.0}, 8)
    assert len(alloc["a"]) == 3 and len(alloc["b"]) == 5


def test_allocate_cores_floors_tiny_weights_at_one_core():
    alloc = allocate_cores({"whale": 100.0, "shrimp": 0.001}, 4)
    assert len(alloc["shrimp"]) == 1 and len(alloc["whale"]) == 3


def test_allocate_cores_infeasible_pool_returns_empty():
    assert allocate_cores({"a": 1, "b": 1, "c": 1}, 2) == {}
    assert allocate_cores({}, 8) == {}


def test_allocate_cores_explicit_survivor_pool():
    """The degraded path passes surviving core ids, not a count."""
    alloc = allocate_cores({"a": 1.0, "b": 1.0}, [5, 2, 7, 0])
    assert alloc == {"a": (0, 2), "b": (5, 7)}


@pytest.mark.parametrize("weights", [
    {"a": 1, "b": 1, "c": 1},
    {"a": 5, "b": 1, "c": 1},
    {"a": 0.1, "b": 0.2, "c": 0.7},
])
def test_allocate_cores_blocks_partition_the_pool(weights):
    alloc = allocate_cores(weights, 8)
    cores = [c for block in alloc.values() for c in block]
    assert sorted(cores) == list(range(8))      # disjoint and covering
    for block in alloc.values():                # contiguous blocks
        assert list(block) == list(range(block[0], block[-1] + 1))


def test_plan_rebalance_moves_one_core_to_the_pressured_tenant():
    alloc = {"a": (0, 1, 2, 3), "b": (4, 5, 6, 7)}
    move = plan_rebalance(alloc, {"a": 10.0, "b": 500.0})
    assert move == {"from": "a", "to": "b", "counts": {"a": 3, "b": 5}}
    blocks = blocks_from_counts(move["counts"], 8)
    assert blocks == {"a": (0, 1, 2), "b": (3, 4, 5, 6, 7)}


def test_plan_rebalance_respects_avoid_and_donor_floor():
    alloc = {"a": (0,), "b": (1, 2, 3)}
    # b is comm-bound (avoided): a receives instead, b donates
    move = plan_rebalance(alloc, {"a": 9.0, "b": 90.0}, avoid=("b",))
    assert move["to"] == "a" and move["from"] == "b"
    # the only would-be donor holds one core: no legal move
    assert plan_rebalance({"a": (0,), "b": (1,)},
                          {"a": 1.0, "b": 9.0}) is None
    assert plan_rebalance({"a": (0, 1)}, {"a": 1.0}) is None


def test_blocks_from_counts_must_cover_the_pool():
    with pytest.raises(ValueError, match="do not cover"):
        blocks_from_counts({"a": 3, "b": 3}, 8)
    with pytest.raises(ValueError, match=">= 1 core"):
        blocks_from_counts({"a": 0, "b": 8}, 8)


# ---------------------------------------------------------------------------
# tenants + registry
# ---------------------------------------------------------------------------
def test_tenant_validation():
    prog = as_tenant("ok", _spn(6, 3)).prog
    for bad in ("", "a/b", "a:b"):
        with pytest.raises(ValueError, match="tenant name"):
            Tenant(bad, prog=prog)
    with pytest.raises(ValueError, match="qos_weight"):
        Tenant("t", prog=prog, qos_weight=0.0)
    with pytest.raises(ValueError, match="needs a prog"):
        Tenant("t", prog=None)
    with pytest.raises(ValueError, match="name mismatch"):
        as_tenant("x", Tenant("y", prog=prog))


def test_registry_rejects_duplicates_and_reverse_looks_up_digests():
    t1 = as_tenant("one", _spn(6, 4))
    t2 = as_tenant("two", _spn(7, 5))
    reg = ModelRegistry([t1, t2])
    with pytest.raises(ValueError, match="already registered"):
        reg.register(Tenant("one", prog=t1.prog))
    with pytest.raises(KeyError, match="unknown tenant"):
        reg.get("three")
    assert reg.names() == ["one", "two"] and "two" in reg and len(reg) == 2
    assert reg.tenant_of_digest(t2.prog.digest()) == "two"
    assert reg.tenant_of_digest("not-a-digest") is None


# ---------------------------------------------------------------------------
# co-residency on the vliw-mc fabric
# ---------------------------------------------------------------------------
def test_coresident_tenants_get_disjoint_core_sets(duo):
    arts = {n: duo.artifact("marginal", "vliw-mc", tenant=n)
            for n in ("alpha", "beta")}
    labels = {n: set(a.meta["multicore"]["core_labels"])
              for n, a in arts.items()}
    assert labels["alpha"] and labels["beta"]
    assert not (labels["alpha"] & labels["beta"])
    assert len(labels["alpha"] | labels["beta"]) <= 8
    st = duo.stats()
    assert st["tenancy"]["mode"] == "co-resident"
    for n in ("alpha", "beta"):
        assert st["tenancy"]["tenants"][n]["cores"] is not None


def test_coresident_parity_per_tenant(duo):
    """Every tenant's served answers match its oracle on every
    substrate — including checked-sim bit-exactness — through the
    SHARED server."""
    for name in ("alpha", "beta"):
        prog = duo.registry.get(name).prog
        verify_parity(duo, _evidence(prog.num_vars, n=8, seed=3),
                      query="marginal", substrates=SUBSTRATES,
                      tenant=name)


@pytest.mark.parametrize("substrate", SUBSTRATES)
def test_interleaved_submits_match_synchronous_queries(duo, substrate):
    """Chunked submits interleaved across tenants resolve to exactly
    the values the per-tenant synchronous query path returns."""
    X = {n: _evidence(duo.registry.get(n).prog.num_vars, n=9, seed=7)
         for n in ("alpha", "beta")}
    ref = {n: duo.query(X[n], "marginal", substrate, tenant=n)
           for n in X}
    pend = {n: [] for n in X}
    for lo in range(0, 9, 3):               # alpha/beta chunks interleaved
        for n in X:
            pend[n].append(
                duo.submit(X[n][lo:lo + 3], "marginal", substrate,
                           tenant=n))
    duo.flush()
    for n in X:
        got = np.concatenate([p.result() for p in pend[n]])
        assert np.array_equal(got, ref[n]), \
            f"{substrate}/{n}: interleaved != synchronous"


def test_threaded_tenants_with_pump_thread(duo):
    """N tenant threads submitting concurrently, resolved only by the
    background pump — no caller ever flushes — still bit-exact."""
    X = {n: _evidence(duo.registry.get(n).prog.num_vars, n=8, seed=11)
         for n in ("alpha", "beta")}
    ref = {n: duo.query(X[n], "marginal", "numpy", tenant=n) for n in X}
    results: dict[str, list] = {n: [] for n in X}

    def client(n):
        pend = [duo.submit(X[n][lo:lo + 2], "marginal", "numpy", tenant=n)
                for lo in range(0, 8, 2)]
        for p in pend:
            assert p.wait(5.0), f"{n}: pump never resolved the request"
            results[n].append(p.result())

    duo.flush_max_age_s = 0.01
    duo.start_pump()
    try:
        threads = [threading.Thread(target=client, args=(n,)) for n in X]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10.0)
            assert not t.is_alive()
    finally:
        duo.stop_pump()
        duo.flush_max_age_s = None
    for n in X:
        assert np.array_equal(np.concatenate(results[n]), ref[n])


def test_age_deadline_flush_without_explicit_flush(duo):
    """pump() with an aged clock resolves queued work that neither hit
    the rows high-water mark nor saw flush()/result()."""
    X = _evidence(duo.registry.get("alpha").prog.num_vars, n=2, seed=13)
    p = duo.submit(X, "marginal", "numpy", tenant="alpha")
    assert not p.ready()
    assert duo.pump(now=time.monotonic(), max_age_s=3600.0) == 0
    assert not p.ready()                    # young request: not due yet
    assert duo.pump(now=time.monotonic() + 7200.0, max_age_s=3600.0) >= 1
    assert p.ready() and p.result().shape == (2,)


def test_qos_weights_skew_core_allocation():
    srv = Server(tenants={"hi": Tenant("hi", prog=None, spn=_spn(8, 21),
                                       qos_weight=3.0),
                          "lo": Tenant("lo", prog=None, spn=_spn(8, 22),
                                       qos_weight=1.0)},
                 substrates=("numpy", "vliw-mc"), cores=8,
                 topology="mesh")
    hi = srv.registry.get("hi").cores
    lo = srv.registry.get("lo").cores
    assert len(hi) == 6 and len(lo) == 2
    assert not (set(hi) & set(lo))


def test_stats_keys_disambiguate_coresident_tenants(duo):
    """Two co-resident SPNs with the SAME semiring/substrate pair must
    land in distinct stats entries — the pre-tenancy keying silently
    overwrote one with the other."""
    for n in ("alpha", "beta"):
        X = _evidence(duo.registry.get(n).prog.num_vars, n=4, seed=17)
        duo.query(X, "marginal", "vliw-mc", tenant=n)
    st = duo.stats()
    for section in ("batchers", "multicore"):
        keys = [k for k in st[section] if k.endswith("sum/vliw-mc")]
        assert "alpha/sum/vliw-mc" in keys and "beta/sum/vliw-mc" in keys
    a = st["multicore"]["alpha/sum/vliw-mc"]
    b = st["multicore"]["beta/sum/vliw-mc"]
    assert not (set(a["core_labels"]) & set(b["core_labels"]))
    # per-tenant SLO keys recorded next to the aggregate
    assert {"vliw-mc/sum", "alpha:vliw-mc/sum",
            "beta:vliw-mc/sum"} <= set(st["slo"])


def test_single_tenant_stats_keys_unchanged(duo):
    srv = Server(_spn(8, 31), substrates=("numpy",))
    srv.query(_evidence(8, n=3, seed=19), "marginal", "numpy")
    assert "sum/numpy" in srv.stats()["batchers"]      # no tenant prefix


def test_unknown_tenant_is_a_client_error(duo):
    X = _evidence(8, n=2, seed=23)
    with pytest.raises(KeyError, match="unknown tenant"):
        duo.submit(X, "marginal", "numpy", tenant="nobody")
    with pytest.raises(KeyError, match="unknown tenant"):
        duo.query(X, "marginal", "numpy", tenant="nobody")


def test_rebalance_ratchets_on_weighted_makespan():
    srv = Server(tenants={"big": _spn(12, 41), "small": _spn(6, 42)},
                 substrates=("numpy", "vliw-mc"), cores=8,
                 topology="mesh")
    ev = srv.rebalance(query="marginal")
    assert ev is not None
    assert ev["applied"] == (ev["candidate_makespan"] < ev["makespan"])
    if ev["applied"]:
        blocks = [set(srv.registry.get(n).cores)
                  for n in ("big", "small")]
        assert not (blocks[0] & blocks[1])        # still disjoint
        assert len(blocks[0] | blocks[1]) <= 8
    # the ratchet is monotone: a second pass never adopts a move that
    # worsens the makespan the first pass settled on
    ev2 = srv.rebalance(query="marginal")
    if ev2 is not None and ev2["applied"]:
        assert ev2["candidate_makespan"] < ev2["makespan"]
    events = [e for e in srv.stats()["tenancy"]["events"]
              if e["kind"] == "rebalance"]
    assert len(events) >= 2
