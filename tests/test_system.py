"""End-to-end system tests: the full stack wired together.

- SPN path: learn → lower → compile → three backends agree (paper fig. 1
  deployment path).
- LM path: trainer runs, loss decreases, checkpoint/restart resumes to the
  SAME final state as an uninterrupted run (fault-tolerance contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executors, learn, program
from repro.core.compiler.pipeline import compile_program
from repro.core.processor import sim
from repro.core.processor.config import PTREE
from repro.data import spn_datasets
from repro.kernels.spn_eval import spn_eval
from repro.launch.train import TrainConfig, Trainer
from repro.runtime import FailureInjector, run_with_restarts


def test_spn_end_to_end():
    X = spn_datasets.load("nltcs", "train", 300)
    net = learn.learn_spn(X, min_instances=80)
    prog = program.lower(net)
    Xq = spn_datasets.load("nltcs", "test", 32)
    leaves = prog.leaves_from_evidence(Xq)
    ref = executors.eval_ops_numpy(prog, leaves)
    # backend 1: leveled JAX
    lvl = np.asarray(executors.eval_leveled(prog, leaves.astype(np.float32)))
    # backend 2: Pallas kernel
    ker = np.asarray(spn_eval(prog, leaves.astype(np.float32)))
    # backend 3: custom processor (compile + cycle-accurate sim)
    vprog = compile_program(prog, PTREE)
    res = sim.simulate(vprog, prog, Xq, PTREE)
    np.testing.assert_allclose(lvl, ref, rtol=1e-4)
    np.testing.assert_allclose(ker, ref, rtol=1e-4)
    np.testing.assert_allclose(res.root_values, ref, rtol=1e-4)
    assert res.ops_per_cycle > 1.0


@pytest.mark.slow
def test_trainer_loss_decreases(tmp_path):
    tc = TrainConfig(arch="qwen2-0.5b", steps=12, global_batch=4, seq_len=32,
                     ckpt_dir=None)
    tr = Trainer(tc)
    out = tr.run(tr.init_state())
    assert np.mean(out["losses"][-4:]) < np.mean(out["losses"][:4])


@pytest.mark.slow
def test_restart_resumes_identically(tmp_path):
    """Crash at step 7, restart from checkpoint → same final params as an
    uninterrupted run (bitwise, since data order is checkpointed)."""
    common = dict(arch="qwen2-0.5b", steps=10, global_batch=4, seq_len=32,
                  ckpt_every=5)

    # uninterrupted
    tc0 = TrainConfig(ckpt_dir=str(tmp_path / "a"), **common)
    t0 = Trainer(tc0)
    ref = t0.run(t0.init_state())

    # crashing run + restart harness
    tc1 = TrainConfig(ckpt_dir=str(tmp_path / "b"), **common)
    inj = FailureInjector({7})

    def make():
        t = Trainer(tc1, injector=inj)
        return ("fresh", t)

    def resume():
        t = Trainer(tc1, injector=inj)
        st = t.resume_state()
        return ("resumed", t) if st is not None else None

    def run(pack):
        kind, t = pack
        st = t.resume_state() if kind == "resumed" else t.init_state()
        return t.run(st)

    out = run_with_restarts(make, resume, run)
    assert out["step"] == 10
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    """accum=2 over the same global batch ≈ single-step gradients."""
    from repro.configs import get_smoke_config
    from repro.launch import step_fns
    from repro.models import api
    from repro.optim import AdamWConfig, adamw

    cfg = get_smoke_config("qwen2-0.5b")
    opt_cfg = AdamWConfig(lr=0.0, warmup_steps=0, weight_decay=0.0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                   jnp.int32)}
    f1 = step_fns.make_train_step(cfg, opt_cfg, remat=False)
    f2 = step_fns.make_grad_accum_step(cfg, opt_cfg, 2, remat=False)
    o1 = f1(params, adamw.init_state(params), batch)
    o2 = f2(params, adamw.init_state(params), batch)
    # loss metrics agree (mean over microbatches == full-batch mean here
    # because microbatches are equal-sized)
    np.testing.assert_allclose(float(o1[2]["loss"]), float(o2[2]["loss"]),
                               rtol=1e-3)
